"""Model architecture configuration covering all assigned families.

One ``ModelConfig`` describes any of: dense GQA decoder LMs (qwen*,
granite), fine-grained MoE (deepseek, kimi), attention-free SSM (mamba2),
hybrid SSM+attention+MoE (jamba), encoder-decoder with a stub audio
frontend (whisper), and a decoder LM with a stub vision frontend
(internvl). The family string selects the forward builder in
:mod:`repro.models.registry`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    shared_experts: int = 0
    expert_d_ff: int = 0          # per-expert FFN width
    capacity_factor: float = 1.25
    capacity_factor_decode: float = 2.0   # decode batches are small; give
                                          # routing more headroom
    router_aux_coef: float = 0.01
    every_k_layers: int = 1       # 1 = every layer is MoE


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    d_conv: int = 4
    chunk: int = 256              # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    head_dim: Optional[int] = None
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    attn_period: int = 0          # hybrid: 1 attention layer per this many
    attn_offset: int = 4          # hybrid: position of attn inside a period
    enc_layers: int = 0           # encdec: encoder depth
    enc_seq: int = 1500           # encdec: encoder frames (whisper stub)
    vis_tokens: int = 0           # vlm: prepended patch-embedding tokens
    q_block: int = 512            # flash-attention query block
    dtype: str = "bfloat16"
    # which serving shapes are valid for this arch (full attention at 500k
    # sequence length is quadratic -> skipped per the brief)
    supports_long_context: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to 128 so the embedding shards evenly on any TP
        degree up to 128 (published sizes like 49155 are not divisible by
        16). Padded logit columns are masked to -inf before softmax."""
        return -(-self.vocab // 128) * 128

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def param_count(self) -> int:
        """Analytic parameter count (embedding + layers), for 6ND."""
        D, F, V, hd = self.d_model, self.d_ff, self.vocab, self.hd
        emb = V * D if self.tie_embeddings else 2 * V * D
        total = emb

        def attn_params():
            p = D * (self.n_heads * hd) + 2 * D * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * D
            if self.qkv_bias:
                p += self.n_heads * hd + 2 * self.n_kv_heads * hd
            return p

        def dense_ffn():
            return 3 * D * F

        def moe_ffn():
            m = self.moe
            p = D * m.num_experts                                  # router
            p += m.num_experts * 3 * D * m.expert_d_ff             # routed
            p += m.shared_experts * 3 * D * m.expert_d_ff          # shared
            return p

        def ssm_params():
            s = self.ssm
            di = s.d_inner(D)
            nh = s.n_heads(D)
            p = D * di * 2                 # Wx, Wz
            p += 2 * D * s.d_state         # WB, WC
            p += D * nh                    # Wdt
            p += nh * 3                    # A, D, dt_bias
            p += s.d_conv * (di + 2 * s.d_state)
            p += di * D                    # out_proj
            return p

        for layer in range(self.n_layers):
            if self.family == "ssm":
                total += ssm_params() + 2 * D
                continue
            if self.family == "hybrid":
                is_attn = (layer % self.attn_period) == self.attn_offset
                total += (attn_params() if is_attn else ssm_params())
                # MoE cadence follows the config (jamba: every_k_layers=2
                # -> odd positions), matching _init_hybrid_superblock
                k = self.moe.every_k_layers if self.moe is not None else 0
                is_moe = k > 0 and (layer % k) == (k - 1)
                total += (moe_ffn() if is_moe else dense_ffn()) + 3 * D
                continue
            # dense / moe / vlm / encdec decoder layers
            total += attn_params() + 2 * D
            if self.moe is not None and (layer % self.moe.every_k_layers == 0):
                total += moe_ffn()
            else:
                total += dense_ffn()
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            total += self.enc_layers * (attn_params() + dense_ffn() + 4 * D)
            total += self.n_layers * attn_params()   # cross-attention
        return total

    def active_param_count(self) -> int:
        """Active (per-token) parameters, for MoE MODEL_FLOPS."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full_experts = self.n_layers * m.num_experts * 3 * self.d_model * m.expert_d_ff
        if self.family == "hybrid":
            k = m.every_k_layers
            n_moe_layers = sum(1 for l in range(self.n_layers)
                               if l % k == k - 1)
            full_experts = n_moe_layers * m.num_experts * 3 * self.d_model * m.expert_d_ff
            active = n_moe_layers * m.top_k * 3 * self.d_model * m.expert_d_ff
        else:
            active = self.n_layers * m.top_k * 3 * self.d_model * m.expert_d_ff
        return self.param_count() - full_experts + active
