"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layer parameters are stacked on a leading layer axis (``vmap`` at init)
and consumed with ``lax.scan`` so the lowered HLO contains one layer body
regardless of depth — essential to keep 512-device AOT compiles fast.

Public entry points (all pure):
  init_lm(key, cfg)                              -> params
  lm_loss(params, cfg, batch, rng)               -> (loss, metrics)
  lm_prefill(params, cfg, tokens, ...)           -> (logits_last, cache)
  lm_decode(params, cfg, token, cache, position) -> (logits, cache)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from .config import ModelConfig
from . import layers as L
from . import ssm as S
from repro.parallel.hints import constrain


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    """One layer's params. kind: "attn" | "ssm"; FFN chosen by cfg/moe."""
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": L.init_rmsnorm(cfg.d_model)}
    if kind == "ssm":
        p["mamba"] = S.init_mamba(ks[0], cfg)
        return p
    p["attn"] = L.init_attention(ks[0], cfg)
    p["ln2"] = L.init_rmsnorm(cfg.d_model)
    if cfg.moe is not None:
        p["moe"] = L.init_moe(ks[1], cfg)
    else:
        p["ffn"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                              cfg.activation_dtype)
    return p


def _init_hybrid_superblock(key, cfg: ModelConfig) -> Dict[str, Any]:
    """One jamba-style super-block of ``attn_period`` layers."""
    ks = jax.random.split(key, cfg.attn_period * 3)
    p: Dict[str, Any] = {}
    for pos in range(cfg.attn_period):
        kind = "attn" if pos == cfg.attn_offset else "ssm"
        sub: Dict[str, Any] = {"ln1": L.init_rmsnorm(cfg.d_model)}
        if kind == "attn":
            sub["attn"] = L.init_attention(ks[3 * pos], cfg)
        else:
            sub["mamba"] = S.init_mamba(ks[3 * pos], cfg)
        # FFN on every layer: MoE every ``every_k_layers`` positions
        # (jamba's k=2 puts MoE on odd positions, dense on even).
        sub["ln2"] = L.init_rmsnorm(cfg.d_model)
        k_moe = cfg.moe.every_k_layers if cfg.moe is not None else 0
        if cfg.moe is not None and pos % k_moe == k_moe - 1:
            sub["moe"] = L.init_moe(ks[3 * pos + 1], cfg)
        else:
            sub["ffn"] = L.init_mlp(ks[3 * pos + 1], cfg.d_model, cfg.d_ff,
                                    cfg.activation_dtype)
        p[f"pos{pos}"] = sub
    return p


def init_lm(key, cfg: ModelConfig):
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    dt = cfg.activation_dtype
    params: Dict[str, Any] = {
        "embed": L.dense_init(k_emb, (cfg.padded_vocab, cfg.d_model),
                              cfg.d_model, dt),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            k_head, (cfg.d_model, cfg.padded_vocab), cfg.d_model, dt)
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_period
        keys = jax.random.split(k_layers, n_super)
        params["superblocks"] = jax.vmap(
            lambda k: _init_hybrid_superblock(k, cfg))(keys)
    else:
        kind = "ssm" if cfg.family == "ssm" else "attn"
        keys = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: _init_block(k, cfg, kind))(keys)
    return params


# ----------------------------------------------------------------------
# Blocks (forward)
# ----------------------------------------------------------------------

def _apply_ffn(x, p, cfg: ModelConfig, decode: bool = False,
               ep_exchange=None):
    """Post-attention FFN (dense or MoE). x: (B, S, D) -> (out, aux).

    ``ep_exchange`` (PR 8): the expert-parallel all-to-all combine wire,
    threaded from the train step (see :func:`repro.models.layers.moe_ffn`);
    train-path only, decode keeps the local combine.
    """
    B, Sq, D = x.shape
    if "moe" in p:
        cf = cfg.moe.capacity_factor_decode if decode else None
        out, aux = L.moe_ffn(x.reshape(B * Sq, D), p["moe"], cfg.moe,
                             capacity_factor=cf,
                             ep_exchange=None if decode else ep_exchange)
        return out.reshape(B, Sq, D), aux
    return L.mlp(x, p["ffn"]), jnp.float32(0.0)


def _attn_block(x, p, cfg: ModelConfig, positions, ep_exchange=None):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    o, kv = L.attention_train(h, p["attn"], cfg, positions=positions)
    x = x + o
    h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    ff, aux = _apply_ffn(h, p, cfg, ep_exchange=ep_exchange)
    return x + ff, aux, kv


def _ssm_block(x, p, cfg: ModelConfig, ep_exchange=None):
    h = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    x = x + S.mamba_forward(h, p["mamba"], cfg)
    if "ln2" in p:
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        ff, aux = _apply_ffn(h, p, cfg, ep_exchange=ep_exchange)
        return x + ff, aux
    return x, jnp.float32(0.0)


# ----------------------------------------------------------------------
# Train forward
# ----------------------------------------------------------------------

def _embed(params, cfg: ModelConfig, tokens, vis_embed=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if vis_embed is not None:
        x = jnp.concatenate([vis_embed.astype(x.dtype), x], axis=1)
    return constrain(x, ("dp", None, None))


def _unembed(params, cfg: ModelConfig, x):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (x @ head).astype(jnp.float32)
    logits = L.mask_padded_vocab(logits, cfg)
    return constrain(logits, ("dp", None, "tp"))


def lm_hidden(params, cfg: ModelConfig, tokens, vis_embed=None,
              remat: str = "none", ep_exchange=None):
    """Token (+ visual prefix) embedding through all blocks. -> (x, aux)."""
    x = _embed(params, cfg, tokens, vis_embed)
    Sq = x.shape[1]

    # positions is built *inside* each scan body: hoisted outside, the
    # iota becomes a scan-level constant operand whose replicated sharding
    # annotation aborts the 0.4.x partitioner in partial-auto manual
    # regions (see repro.compat); in-body it is a plain iota op.
    def _positions():
        return jnp.arange(Sq)[None, :]

    if cfg.family == "hybrid":
        def super_body(carry, p_sb):
            xx, aux = carry
            for pos in range(cfg.attn_period):
                sub = p_sb[f"pos{pos}"]
                if pos == cfg.attn_offset:
                    xx, a, _ = _attn_block(xx, sub, cfg, _positions(),
                                           ep_exchange=ep_exchange)
                else:
                    xx, a = _ssm_block(xx, sub, cfg,
                                       ep_exchange=ep_exchange)
                aux = aux + a
            return (xx, aux), None
        body = super_body
        stacked = params["superblocks"]
    elif cfg.family == "ssm":
        def body(carry, p_l):
            xx, aux = carry
            xx, a = _ssm_block(xx, p_l, cfg, ep_exchange=ep_exchange)
            return (xx, aux + a), None
        stacked = params["layers"]
    else:
        def body(carry, p_l):
            xx, aux = carry
            xx, a, _ = _attn_block(xx, p_l, cfg, _positions(),
                                   ep_exchange=ep_exchange)
            return (xx, aux + a), None
        stacked = params["layers"]

    if remat == "block":
        body = compat.checkpoint(body, prevent_cse=False)
    elif remat == "block_nocse":
        body = compat.checkpoint(body)
    elif remat == "dots":
        body = compat.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            remat: str = "none", ep_exchange=None
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Causal-LM cross entropy. batch: tokens (B,S), labels (B,S),
    optional vis_embed (B,V,D). Loss only over token positions.
    ``ep_exchange``: the PR 8 expert-parallel combine wire (see
    :func:`lm_hidden` / :func:`repro.models.layers.moe_ffn`)."""
    tokens, labels = batch["tokens"], batch["labels"]
    vis = batch.get("vis_embed")
    x, aux = lm_hidden(params, cfg, tokens, vis, remat=remat,
                       ep_exchange=ep_exchange)
    if vis is not None:
        x = x[:, vis.shape[1]:]                     # text positions only
    logits = _unembed(params, cfg, x)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - ll)
    zloss = 1e-4 * jnp.mean(jnp.square(lse))
    loss = nll + zloss + 0.01 * aux
    return loss, {"nll": nll, "aux": aux, "zloss": zloss}


# ----------------------------------------------------------------------
# Serving: prefill + decode with caches
# ----------------------------------------------------------------------

def init_cache(params, cfg: ModelConfig, batch: int, max_len: int,
               dtype=None):
    """Allocate the per-layer decode cache pytree."""
    dt = dtype or cfg.activation_dtype
    KV, hd = cfg.n_kv_heads, cfg.hd
    if cfg.family == "ssm":
        st = S.init_mamba_state(batch, cfg)
        return {"ssm": jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), st)}
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // cfg.attn_period
        st = S.init_mamba_state(batch, cfg)
        mamba = jax.tree.map(
            lambda a: jnp.zeros((n_super, cfg.attn_period - 1) + a.shape,
                                a.dtype), st)
        kv = {"k": jnp.zeros((n_super, batch, max_len, KV, hd), dt),
              "v": jnp.zeros((n_super, batch, max_len, KV, hd), dt)}
        return {"mamba": mamba, "kv": kv}
    # dense / moe / vlm
    return {"k": jnp.zeros((cfg.n_layers, batch, max_len, KV, hd), dt),
            "v": jnp.zeros((cfg.n_layers, batch, max_len, KV, hd), dt)}


def lm_decode(params, cfg: ModelConfig, token: jnp.ndarray, cache,
              position) -> Tuple[jnp.ndarray, Any]:
    """One decode step. token: (B,) int32; position: scalar int32 (tokens
    0..position-1 are already in the cache). Returns (logits (B,V), cache)."""
    x = _embed(params, cfg, token[:, None])

    if cfg.family == "ssm":
        def body(xx, inp):
            p_l, st = inp
            h = L.rmsnorm(xx, p_l["ln1"], cfg.norm_eps)
            o, st2 = S.mamba_decode(h, p_l["mamba"], cfg, st)
            return xx + o, st2
        x, new_st = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        new_cache = {"ssm": new_st}
    elif cfg.family == "hybrid":
        def body(xx, inp):
            p_sb, mamba_st, kv = inp
            new_states = []
            si = 0
            k_c, v_c = kv["k"], kv["v"]
            for pos in range(cfg.attn_period):
                sub = p_sb[f"pos{pos}"]
                h = L.rmsnorm(xx, sub["ln1"], cfg.norm_eps)
                if pos == cfg.attn_offset:
                    o, k_c, v_c = L.attention_decode(
                        h, sub["attn"], cfg, k_c, v_c, position)
                else:
                    st = jax.tree.map(lambda a: a[si], mamba_st)
                    o, st2 = S.mamba_decode(h, sub["mamba"], cfg, st)
                    new_states.append(st2)
                    si += 1
                xx = xx + o
                h = L.rmsnorm(xx, sub["ln2"], cfg.norm_eps)
                ff, _ = _apply_ffn(h, sub, cfg, decode=True)
                xx = xx + ff
            stacked_st = jax.tree.map(lambda *a: jnp.stack(a), *new_states)
            return xx, (stacked_st, {"k": k_c, "v": v_c})
        x, (new_mamba, new_kv) = jax.lax.scan(
            body, x, (params["superblocks"], cache["mamba"], cache["kv"]))
        new_cache = {"mamba": new_mamba, "kv": new_kv}
    else:
        def body(xx, inp):
            p_l, k_c, v_c = inp
            h = L.rmsnorm(xx, p_l["ln1"], cfg.norm_eps)
            o, k_c, v_c = L.attention_decode(h, p_l["attn"], cfg, k_c, v_c,
                                             position)
            xx = xx + o
            h = L.rmsnorm(xx, p_l["ln2"], cfg.norm_eps)
            ff, _ = _apply_ffn(h, p_l, cfg, decode=True)
            return xx + ff, (k_c, v_c)
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x)[:, 0]
    return logits, new_cache


def lm_prefill(params, cfg: ModelConfig, tokens, max_len: Optional[int] = None,
               vis_embed=None):
    """Prefill: run the full prompt, return (last logits, populated cache).

    For attention families the per-layer K/V are collected from the train
    forward; SSM caches replay the chunked scan's final state.
    """
    B, Sq = tokens.shape
    max_len = max_len or Sq
    x = _embed(params, cfg, tokens, vis_embed)
    Sfull = x.shape[1]
    positions = jnp.arange(Sfull)[None, :]

    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        def body(xx, p_l):
            h = L.rmsnorm(xx, p_l["ln1"], cfg.norm_eps)
            o, (k, v) = L.attention_train(h, p_l["attn"], cfg, positions)
            xx = xx + o
            h = L.rmsnorm(xx, p_l["ln2"], cfg.norm_eps)
            ff, _ = _apply_ffn(h, p_l, cfg)
            return xx + ff, (k, v)
        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        pad = max(0, max_len - Sfull)   # vlm prefix may exceed max_len
        cache = {"k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
                 "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))}
    elif cfg.family == "ssm":
        def body(xx, p_l):
            h = L.rmsnorm(xx, p_l["ln1"], cfg.norm_eps)
            o, st = S.mamba_forward(h, p_l["mamba"], cfg, return_state=True)
            return xx + o, st
        x, states = jax.lax.scan(body, x, params["layers"])
        cache = {"ssm": states}
    elif cfg.family == "hybrid":
        def body(xx, p_sb):
            sts, kv = [], None
            for pos in range(cfg.attn_period):
                sub = p_sb[f"pos{pos}"]
                h = L.rmsnorm(xx, sub["ln1"], cfg.norm_eps)
                if pos == cfg.attn_offset:
                    o, kv = L.attention_train(h, sub["attn"], cfg, positions)
                else:
                    o, st = S.mamba_forward(h, sub["mamba"], cfg,
                                            return_state=True)
                    sts.append(st)
                xx = xx + o
                h = L.rmsnorm(xx, sub["ln2"], cfg.norm_eps)
                ff, _ = _apply_ffn(h, sub, cfg)
                xx = xx + ff
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *sts)
            return xx, (stacked, kv)
        x, (mamba_st, (ks, vs)) = jax.lax.scan(body, x, params["superblocks"])
        pad = max(0, max_len - Sfull)
        cache = {"mamba": mamba_st,
                 "kv": {"k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
                        "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))}}
    else:
        raise NotImplementedError(cfg.family)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x[:, -1:])[:, 0]
    return logits, cache
