"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the brief the conv/mel frontend is a stub: ``input_specs()`` feeds
precomputed frame embeddings (B, enc_seq, D) directly to the encoder.
LayerNorm + GELU MLPs follow Whisper; decoder self-attention uses RoPE
instead of Whisper's learned positions so the 32k decode *shape* cells are
well-defined far beyond the original 448-token context (deviation noted
in DESIGN.md §4).
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from .config import ModelConfig
from . import layers as L
from repro.parallel.hints import constrain


def _init_enc_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_layernorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg),
        "ln2": L.init_layernorm(cfg.d_model),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                          cfg.activation_dtype, gated=False),
    }


def _init_dec_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_layernorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg),
        "ln_x": L.init_layernorm(cfg.d_model),
        "xattn": L.init_attention(ks[1], cfg, cross=True),
        "ln2": L.init_layernorm(cfg.d_model),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                          cfg.activation_dtype, gated=False),
    }


def init_encdec(key, cfg: ModelConfig):
    k_emb, k_enc, k_dec = jax.random.split(key, 3)
    enc_keys = jax.random.split(k_enc, cfg.enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embed": L.dense_init(k_emb, (cfg.padded_vocab, cfg.d_model),
                              cfg.d_model, cfg.activation_dtype),
        "enc_layers": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_ln_post": L.init_layernorm(cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "dec_ln": L.init_layernorm(cfg.d_model),
    }


def _sinusoid(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, enc_seq, D) stub embeddings -> encoder states."""
    x = frames.astype(cfg.activation_dtype)
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)
    x = constrain(x, ("dp", None, None))

    def body(xx, p_l):
        h = L.layernorm(xx, p_l["ln1"], cfg.norm_eps)
        o, _ = L.attention_train(h, p_l["attn"], cfg, causal=False)
        xx = xx + o
        h = L.layernorm(xx, p_l["ln2"], cfg.norm_eps)
        return xx + L.mlp(h, p_l["mlp"]), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layernorm(x, params["enc_ln_post"], cfg.norm_eps)


def _dec_block(xx, p_l, cfg: ModelConfig, enc_out, positions):
    h = L.layernorm(xx, p_l["ln1"], cfg.norm_eps)
    o, kv = L.attention_train(h, p_l["attn"], cfg, positions=positions)
    xx = xx + o
    h = L.layernorm(xx, p_l["ln_x"], cfg.norm_eps)
    o, xkv = L.attention_train(h, p_l["xattn"], cfg, causal=False,
                               kv_input=enc_out)
    xx = xx + o
    h = L.layernorm(xx, p_l["ln2"], cfg.norm_eps)
    return xx + L.mlp(h, p_l["mlp"]), kv, xkv


def encdec_loss(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                remat: str = "none") -> Tuple[jnp.ndarray, Dict]:
    """batch: frames (B, enc_seq, D), tokens (B, S), labels (B, S)."""
    enc_out = encode(params, cfg, batch["frames"])
    tokens, labels = batch["tokens"], batch["labels"]
    x = jnp.take(params["embed"], tokens, axis=0)
    S = x.shape[1]

    def body(xx, p_l):
        # in-body iota: a hoisted positions constant becomes a scan
        # operand whose sharding annotation breaks 0.4.x partial-auto
        # manual regions (see repro.compat)
        out, _, _ = _dec_block(xx, p_l, cfg, enc_out,
                               jnp.arange(S)[None, :])
        return out, None

    if remat in ("block", "dots"):
        body = compat.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.layernorm(x, params["dec_ln"], cfg.norm_eps)
    logits = L.mask_padded_vocab(
        (x @ params["embed"].T).astype(jnp.float32), cfg)
    logits = constrain(logits, ("dp", None, "tp"))
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - ll)
    return nll, {"nll": nll, "aux": jnp.float32(0.0)}


def encdec_prefill(params, cfg: ModelConfig, frames, tokens, max_len: int):
    """Returns (last-token logits, cache). Cache holds decoder self KV
    (updatable) and static cross KV computed once from the encoder."""
    enc_out = encode(params, cfg, frames)
    x = jnp.take(params["embed"], tokens, axis=0)
    Sq = x.shape[1]
    positions = jnp.arange(Sq)[None, :]

    def body(xx, p_l):
        out, kv, xkv = _dec_block(xx, p_l, cfg, enc_out, positions)
        return out, (kv, xkv)

    x, ((ks, vs), (xks, xvs)) = jax.lax.scan(body, x, params["dec_layers"])
    x = L.layernorm(x, params["dec_ln"], cfg.norm_eps)
    logits = L.mask_padded_vocab(
        (x[:, -1:] @ params["embed"].T).astype(jnp.float32), cfg)[:, 0]
    pad = max_len - Sq
    cache = {
        "k": jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "v": jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "xk": xks, "xv": xvs,
    }
    return logits, cache


def init_encdec_cache(params, cfg: ModelConfig, batch: int, max_len: int,
                      dtype=None):
    dt = dtype or cfg.activation_dtype
    KV, hd = cfg.n_kv_heads, cfg.hd
    Ld = cfg.n_layers
    return {
        "k": jnp.zeros((Ld, batch, max_len, KV, hd), dt),
        "v": jnp.zeros((Ld, batch, max_len, KV, hd), dt),
        "xk": jnp.zeros((Ld, batch, cfg.enc_seq, KV, hd), dt),
        "xv": jnp.zeros((Ld, batch, cfg.enc_seq, KV, hd), dt),
    }


def encdec_decode(params, cfg: ModelConfig, token, cache, position):
    """One decoder step with self-attention cache + static cross KV."""
    x = jnp.take(params["embed"], token[:, None], axis=0)

    def body(xx, inp):
        p_l, k_c, v_c, xk, xv = inp
        h = L.layernorm(xx, p_l["ln1"], cfg.norm_eps)
        o, k_c, v_c = L.attention_decode(h, p_l["attn"], cfg, k_c, v_c,
                                         position)
        xx = xx + o
        h = L.layernorm(xx, p_l["ln_x"], cfg.norm_eps)
        xx = xx + L.attention_cross_decode(h, p_l["xattn"], cfg, xk, xv)
        h = L.layernorm(xx, p_l["ln2"], cfg.norm_eps)
        return xx + L.mlp(h, p_l["mlp"]), (k_c, v_c)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.layernorm(x, params["dec_ln"], cfg.norm_eps)
    logits = L.mask_padded_vocab(
        (x @ params["embed"].T).astype(jnp.float32), cfg)[:, 0]
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = nk, nv
    return logits, new_cache
