"""Mamba2 (state-space duality) blocks — chunked SSD train/prefill path
and the O(1)-state decode path.

Follows the minimal SSD formulation of Dao & Gu 2024 (arXiv:2405.21060),
single B/C group shared across heads:

  h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * B_t (x)  (outer product)
  y_t = C_t . h_t + D_h * x_t

Training scans over chunks of length ``Q``: within a chunk the recurrence
is expanded into a (Q, Q) decay-masked quadratic form (MXU-friendly);
across chunks only the (H, P, N) state is carried — sub-quadratic in
sequence length, which is why the ssm/hybrid archs run the 500k cells.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init, rmsnorm, init_rmsnorm
from repro.parallel.hints import constrain


def init_mamba(key, cfg: ModelConfig):
    s = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    nh = s.n_heads(D)
    dt_ = cfg.activation_dtype
    ks = jax.random.split(key, 8)
    conv_ch = di + 2 * s.d_state
    return {
        "wx": dense_init(ks[0], (D, di), D, dt_),
        "wz": dense_init(ks[1], (D, di), D, dt_),
        "wB": dense_init(ks[2], (D, s.d_state), D, dt_),
        "wC": dense_init(ks[3], (D, s.d_state), D, dt_),
        "wdt": dense_init(ks[4], (D, nh), D, dt_),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "conv_w": dense_init(ks[5], (s.d_conv, conv_ch), s.d_conv, jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "norm": init_rmsnorm(di),
        "wo": dense_init(ks[6], (di, D), di, dt_),
    }


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray = None):
    """Depthwise causal conv, window d_conv. u: (B, S, C); w: (d_conv, C).

    With ``state`` (B, d_conv-1, C) the conv continues a stream (decode).
    Returns (y, new_state)."""
    dconv = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], dconv - 1, u.shape[-1]), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)              # (B, S+dc-1, C)
    y = sum(ext[:, i:i + u.shape[1]] * w[i] for i in range(dconv)) + b
    new_state = ext[:, -(dconv - 1):] if dconv > 1 else state
    return jax.nn.silu(y).astype(u.dtype), new_state


def _ssd_chunk_scan(xdt, dA, Bm, Cm, chunk: int):
    """Chunked SSD. xdt: (B,S,H,P) = x*dt;  dA: (B,S,H) = dt*A (negative);
    Bm, Cm: (B,S,N). Returns y (B,S,H,P)."""
    Bt, S, H, Pd = xdt.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S

    def padn(a):
        return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))

    xdt, dA, Bm, Cm = padn(xdt), padn(dA), padn(Bm), padn(Cm)
    xdt = xdt.reshape(Bt, nc, Q, H, Pd).transpose(1, 0, 2, 3, 4)
    dA = dA.reshape(Bt, nc, Q, H).transpose(1, 0, 2, 3)
    Bm = Bm.reshape(Bt, nc, Q, N).transpose(1, 0, 2, 3)
    Cm = Cm.reshape(Bt, nc, Q, N).transpose(1, 0, 2, 3)

    def chunk_step(state, inp):              # noqa: C901 — hot loop
        x_c, dA_c, B_c, C_c = inp            # (B,Q,H,P),(B,Q,H),(B,Q,N)
        cs = jnp.cumsum(dA_c, axis=1)        # (B,Q,H) inclusive
        total = cs[:, -1]                    # (B,H)
        # intra-chunk: decay(i,j) = exp(cs_i - cs_j) for i >= j.
        # Mask the *exponent* (not the product): i < j gives positive
        # diffs that overflow exp and NaN the backward through where().
        diff = cs[:, :, None, :] - cs[:, None, :, :]               # (B,Q,Q,H)
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        diff = jnp.where(causal[None, :, :, None], diff, -1e30)
        dec = constrain(jnp.exp(diff), ("dp", None, None, "tp"))
        scores = jnp.einsum("bin,bjn->bij", C_c, B_c,
                            preferred_element_type=jnp.float32)
        M = constrain(scores[..., None] * dec, ("dp", None, None, "tp"))
        y_diag = constrain(
            jnp.einsum("bijh,bjhp->bihp", M, x_c,
                       preferred_element_type=jnp.float32),
            ("dp", None, "tp", None))
        # contribution of the carried state
        y_off = jnp.einsum("bin,bhpn->bihp", C_c, state,
                           preferred_element_type=jnp.float32) \
            * jnp.exp(cs)[..., None]
        # state update: decay to end of chunk
        w_in = jnp.exp(total[:, None, :] - cs)                     # (B,Q,H)
        new_state = state * jnp.exp(total)[:, :, None, None] \
            + jnp.einsum("bjn,bjhp,bjh->bhpn", B_c, x_c, w_in,
                         preferred_element_type=jnp.float32)
        return new_state, (y_diag + y_off)

    state0 = jnp.zeros((Bt, H, Pd, N), jnp.float32)
    final_state, ys = jax.lax.scan(chunk_step, state0, (xdt, dA, Bm, Cm))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bt, nc * Q, H, Pd)
    return y[:, :S], final_state


def mamba_forward(x: jnp.ndarray, p, cfg: ModelConfig,
                  return_state: bool = False):
    """Train/prefill forward. x: (B, S, D) -> (B, S, D) [, decode state]."""
    s = cfg.ssm
    B, S, D = x.shape
    di = s.d_inner(D)
    nh = s.n_heads(D)
    xz = x @ p["wx"]                                  # (B,S,di)
    z = x @ p["wz"]
    Bm = x @ p["wB"]
    Cm = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    conv_in = jnp.concatenate([xz, Bm.astype(xz.dtype), Cm.astype(xz.dtype)], -1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"].astype(xz.dtype),
                                        p["conv_b"].astype(xz.dtype))
    xz, Bm, Cm = (conv_out[..., :di],
                  conv_out[..., di:di + s.d_state].astype(jnp.float32),
                  conv_out[..., di + s.d_state:].astype(jnp.float32))
    xh = xz.reshape(B, S, nh, s.head_dim).astype(jnp.float32)
    xh = constrain(xh, ("dp", None, "tp", None))
    A = -jnp.exp(p["A_log"])                          # (H,) negative
    y, ssm_state = _ssd_chunk_scan(xh * dt[..., None], dt * A, Bm, Cm, s.chunk)
    y = y + xh * p["D_skip"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["wo"]
    if return_state:
        return out, {"ssm": ssm_state, "conv": conv_state.astype(jnp.float32)}
    return out


def init_mamba_state(batch: int, cfg: ModelConfig, dtype=jnp.float32):
    s = cfg.ssm
    nh = s.n_heads(cfg.d_model)
    return {
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), dtype),
        "conv": jnp.zeros((batch, s.d_conv - 1,
                           s.d_inner(cfg.d_model) + 2 * s.d_state), dtype),
    }


def mamba_decode(x: jnp.ndarray, p, cfg: ModelConfig, state):
    """Single-token decode. x: (B, 1, D). Returns (y, new_state)."""
    s = cfg.ssm
    B, _, D = x.shape
    di = s.d_inner(D)
    nh = s.n_heads(D)
    xz = x @ p["wx"]
    z = x @ p["wz"]
    Bm = x @ p["wB"]
    Cm = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    conv_in = jnp.concatenate([xz, Bm.astype(xz.dtype), Cm.astype(xz.dtype)], -1)
    conv_out, conv_state = _causal_conv(
        conv_in, p["conv_w"].astype(xz.dtype), p["conv_b"].astype(xz.dtype),
        state["conv"].astype(xz.dtype))
    xz = conv_out[..., :di]
    Bm = conv_out[..., di:di + s.d_state].astype(jnp.float32)[:, 0]
    Cm = conv_out[..., di + s.d_state:].astype(jnp.float32)[:, 0]
    xh = xz.reshape(B, nh, s.head_dim).astype(jnp.float32)
    dt0 = dt[:, 0]                                    # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt0 * A)                             # (B,H)
    h = state["ssm"] * dA[:, :, None, None] \
        + jnp.einsum("bn,bhp,bh->bhpn", Bm, xh, dt0)
    y = jnp.einsum("bn,bhpn->bhp", Cm, h) + xh * p["D_skip"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["wo"], {"ssm": h.astype(state["ssm"].dtype),
                         "conv": conv_state.astype(state["conv"].dtype)}
