"""Model zoo: dense GQA, MoE, Mamba2 SSD, hybrid (Jamba-style), whisper
enc-dec, VLM-stub — all as pure-functional JAX modules with scan-stacked
layers."""

from .config import ModelConfig, MoEConfig, SSMConfig
from .registry import model_api, ModelAPI

__all__ = ["ModelConfig", "MoEConfig", "SSMConfig", "model_api", "ModelAPI"]
