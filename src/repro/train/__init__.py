"""Training substrate: optimizer, step builder (manual-DP shard_map +
compressed aggregation + ZeRO-1), loop with fault tolerance."""

from .config import TrainConfig
from .optimizer import OptimizerConfig
from .step import TrainState, init_train_state, build_train_step

__all__ = ["TrainConfig", "OptimizerConfig", "TrainState",
           "init_train_state", "build_train_step"]
