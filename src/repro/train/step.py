"""Train-step builder: manual-DP ``shard_map`` around auto-TP GSPMD.

The step is organised exactly like the paper's Algorithm 1 deployment:

  1. each (pod, data) worker computes *local* gradients (auto TP inside);
  2. gradients are aggregated across the DP axes by a pluggable
     :class:`~repro.core.aggregators.Aggregator` strategy selected by
     ``tc.aggregator`` — ``"dense"`` (plain ``psum``, the NCCL-baseline
     arm), ``"compressed"`` (the paper's pipeline over fixed-size
     gradient buckets: ONE sketch encode + ONE stacked sketch-``psum`` +
     ONE index OR-AllReduce for the whole pytree, optionally pipelined
     per wire chunk through the shared stream scheduler —
     ``cfg.overlap`` / ``cfg.stream_chunks``, ``core/streams.py``),
     ``"compressed_rs"`` (the reduce-scatter wire: ``psum_scatter``
     sketch + OR-Reduce-Scatter bitmap where supported, so each DP rank
     receives and peels only its own 1/W bucket range — the natural
     partner of the ZeRO-1 sharded optimizer, including the PR 5
     gather-skip path: when the stream chunk grid aligns with the
     ZeRO-1 slices, per-rank recovered chunks feed the optimizer
     shards directly and the recovered-chunk all_gather disappears
     (``tc.rs_gather_skip``); emulated by psum + slice on 0.4.x
     partial-auto), or
     ``"compressed_innet"`` (the emulated in-network tier of PR 4: the
     stream rides a worker->ToR->spine switch tree from ``repro.net``
     once per worker — integer-add sketch over the fixed-point wire
     when ``compression.wire_dtype='fxp32'``, OR bitmap — so the
     hottest link carries 1x the payload vs the ring's 2(W-1)/W x), or
     ``"auto"`` (PR 6: per-bucket-group wire selection — the step
     executes a ``WirePlan`` from the host-side cost-model controller,
     passed via ``build_train_step(..., wire_plan=...)``, and surfaces
     per-bucket occupancy telemetry back through the metrics);
  3. the optimizer applies the aggregated gradient — replicated, or
     ZeRO-1-sharded across the DP axes (slice-update-allgather).

Error-feedback residuals keep the parameter pytree layout (sparsification
is per leaf — see ``core/aggregators``); the bucketed strategies expose
per-bucket residual views through ``BucketPlan.residual_slices``.

Everything lives in one jittable function so the multi-pod dry-run can
``lower().compile()`` it with placeholder inputs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro import compat
from repro.core import aggregators as agg_lib
from repro.core import collectives as coll
from repro.core import streams as streams_lib
from repro.models.registry import ModelAPI
from repro.parallel import sharding as shd
from repro.parallel.hints import logical_axis_rules
from .config import TrainConfig
from . import optimizer as opt_lib


# ----------------------------------------------------------------------
# Train state (a pytree)
# ----------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    residual: Any          # EF residuals, leading dp axis (or (0,) stubs)
    step: jnp.ndarray


def effective_dp_axes(prof, mesh) -> tuple:
    """dp axes restricted to those the mesh actually has."""
    return tuple(a for a in prof.dp_axes if a in mesh.shape)


def _dp_total(mesh, dp_axes) -> int:
    n = 1
    for a in dp_axes:
        n *= mesh.shape[a]
    return n


def init_train_state(api: ModelAPI, tc: TrainConfig, mesh, key) -> TrainState:
    params = api.init(key)
    opt = opt_lib.init_opt_state(params, tc.optimizer)
    dp = _dp_total(mesh, effective_dp_axes(tc.sharding, mesh))
    ccfg = tc.compression
    if tc.aggregator != "dense" and ccfg.topk_ratio is not None \
            and ccfg.error_feedback:
        residual = jax.tree.map(
            lambda p: jnp.zeros((dp,) + p.shape, jnp.float32), params)
    else:
        residual = jax.tree.map(lambda p: jnp.zeros((0,), jnp.float32), params)
    return TrainState(params=params, opt=opt, residual=residual,
                      step=jnp.zeros((), jnp.int32))


# ----------------------------------------------------------------------
# Sharding trees for the state / batch
# ----------------------------------------------------------------------

# The ZeRO-1 slice-dim rule lives in core/streams.py: the reduce-scatter
# aggregator's gather-skip predicate checks alignment against the exact
# same definition, so the slice the optimizer consumes and the slice the
# aggregator validates can never drift apart.
def _zero_slice_dim(shape, spec: P, dp: int,
                    stacked_dim0: bool = False) -> Optional[int]:
    del stacked_dim0
    return streams_lib.zero_slice_dim(shape, spec, dp)


def state_specs(state: TrainState, tc: TrainConfig, mesh) -> Dict[str, Any]:
    """Returns dict with 'full' (NamedShardings for jit in/out) and
    'manual' (PartitionSpecs over the manual dp axes for shard_map)."""
    prof = tc.sharding
    dp_axes = effective_dp_axes(prof, mesh)
    dp = _dp_total(mesh, dp_axes)
    pspecs = shd.param_pspecs(state.params, prof)

    # params: auto axes only (manual spec is replicated P())
    p_manual = jax.tree.map(lambda s: P(), pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    # optimizer: ZeRO-1 slices on the dp axes where possible
    def opt_specs(param_spec: P, leaf):
        if not prof.zero1 or dp == 1:
            return P(), param_spec
        d = _zero_slice_dim(leaf.shape, param_spec, dp, False)
        if d is None:
            return P(), param_spec
        parts_m = [None] * leaf.ndim
        parts_m[d] = dp_axes if len(dp_axes) > 1 else dp_axes[0]
        manual = P(*parts_m)
        parts_f = list(param_spec) + [None] * (leaf.ndim - len(param_spec))
        parts_f[d] = parts_m[d]
        return manual, P(*parts_f)

    opt_manual, opt_full = {}, {}
    for mom, tree in state.opt.items():
        opt_manual[mom] = jax.tree.map(
            lambda leaf, s: opt_specs(s, leaf)[0], tree, pspecs)
        opt_full[mom] = jax.tree.map(
            lambda leaf, s: opt_specs(s, leaf)[1], tree, pspecs)

    # EF residual: leading dp axis + the param's own tp sharding shifted
    def res_manual(r):
        if r.ndim == 1 and r.shape[0] == 0:
            return P()
        return P(dp_axes if len(dp_axes) > 1 else dp_axes[0])

    def res_full(r, s):
        if r.ndim == 1 and r.shape[0] == 0:
            return P()
        return P(*((dp_axes if len(dp_axes) > 1 else dp_axes[0],) + tuple(s)))

    r_manual = jax.tree.map(res_manual, state.residual)
    r_full = jax.tree.map(res_full, state.residual, pspecs)

    manual = TrainState(params=p_manual, opt=opt_manual, residual=r_manual,
                        step=P())
    full = TrainState(params=pspecs, opt=opt_full, residual=r_full, step=P())
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), full,
                         is_leaf=lambda x: isinstance(x, P))
    return {"manual": manual, "full": full, "named": named,
            "pspecs": pspecs}


def batch_specs(batch_shapes: Dict[str, Any], mesh, tc: TrainConfig):
    """Manual + named shardings for a training batch (dict of arrays).

    The manual spec covers only the DP (shard_map) axes; the named
    sharding additionally spreads the batch over any *auto* batch axes
    (ShardingProfile.batch_auto_axes, e.g. kimi's "data"=EP axis)."""
    prof = tc.sharding
    dp_axes = effective_dp_axes(prof, mesh)
    ax = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    auto = tuple(a for a in prof.batch_auto_axes if a in mesh.shape)
    full_axes = tuple(dp_axes) + auto
    fax = full_axes if len(full_axes) > 1 else (
        full_axes[0] if full_axes else None)

    manual = jax.tree.map(lambda _: P(ax) if ax else P(), batch_shapes)
    named = jax.tree.map(
        lambda _: NamedSharding(mesh, P(fax) if fax else P()), batch_shapes)
    return manual, named


# ----------------------------------------------------------------------
# The step itself
# ----------------------------------------------------------------------

def build_train_step(api: ModelAPI, tc: TrainConfig, mesh, *,
                     wire_plan=None):
    """Returns (step_fn, specs) where step_fn(state, batch) -> (state,
    metrics) is ready for jax.jit with the provided shardings.

    ``wire_plan`` (PR 6): an explicit
    :class:`~repro.core.wireplan.WirePlan` applied to the aggregator —
    how the ``auto`` strategy's host-side controller
    (:class:`~repro.core.costmodel.AutoWireController`) swaps plans in:
    rebuild the step with the new plan every ``replan_every`` boundary
    (each plan is its own compiled step). Ignored when the effective
    strategy is dense (single DP rank, or ``tc.aggregator='dense'``).
    With ``tc.aggregator='auto'`` and no plan, the step executes the
    controller's analytic plan. The ``auto`` aggregator also surfaces
    its per-bucket occupancy telemetry as the (vector-valued)
    ``bucket_occupancy`` metric for the controller to fold back in.
    """
    prof = tc.sharding
    # drop dp axes the mesh doesn't have (e.g. "pod" on a single pod)
    dp_axes = effective_dp_axes(prof, mesh)
    dp = _dp_total(mesh, dp_axes)
    ocfg = tc.optimizer
    inside_rules = shd.filter_rules_for_mesh(
        prof.logical_rules(inside_manual_dp=True), mesh)
    # with no manual axes the step runs under plain jit: constraints must
    # carry the mesh (NamedSharding), not bare PartitionSpecs
    rules_mesh = None if dp_axes else mesh

    def _pin_one(x, spec):
        if rules_mesh is not None:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(rules_mesh, spec))
        return compat.manual_region_constraint(x, spec)

    def local_grads(params, batch, pspecs):
        """Per-worker gradients, with optional microbatch accumulation."""
        def loss_fn(p, b):
            with logical_axis_rules(inside_rules, mesh=rules_mesh):
                # ep_exchange is bound below (after the manual-axes set is
                # known) and read here at trace time, inside the manual
                # region where its collectives are legal.
                if ep_exchange is None:
                    loss, metrics = api.loss(p, b, remat=tc.remat)
                else:
                    loss, metrics = api.loss(p, b, remat=tc.remat,
                                             ep_exchange=ep_exchange)
            return loss, metrics

        def pin(grads):
            # keep the gradient (and its accumulation carry) on the
            # parameters' TP sharding — without this GSPMD can replicate
            # the f32 accumulator (full-size per device)
            return jax.tree.map(_pin_one, grads, pspecs)

        if tc.accum_steps <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, pin(grads)

        def split(x):
            return x.reshape((tc.accum_steps, x.shape[0] // tc.accum_steps)
                             + x.shape[1:])
        micro = jax.tree.map(split, batch)

        def acc_body(carry, mb):
            loss_a, grads_a = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            grads_a = pin(jax.tree.map(jnp.add, grads_a, grads))
            return (loss_a + loss, grads_a), metrics

        g0 = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params))
        (loss_sum, grads), metrics = jax.lax.scan(
            acc_body, (jnp.float32(0.0), g0), micro)
        inv = 1.0 / tc.accum_steps
        grads = jax.tree.map(lambda g: g * inv, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum * inv, metrics, grads

    # Strategy selected once per step build; called inside the manual-DP
    # region. Compression packs shard-locally even in pure-DP profiles:
    # vocab-sharded embedding grads would otherwise be all-gathered to
    # full size before encoding (16+ GiB/step on a 3B model).
    step_manual = compat.train_step_manual_axes(mesh, dp_axes)
    aggregator = agg_lib.make_aggregator(
        tc.aggregator if dp > 1 else "dense", tc.compression, mesh,
        dp_axes=dp_axes, tp_axes=((prof.tp_axis or "model"),),
        outer_manual=step_manual)
    if wire_plan is not None and not isinstance(aggregator,
                                                agg_lib.DenseAggregator):
        aggregator = dataclasses.replace(aggregator, wire_plan=wire_plan)
    # Full-manual step regions (0.4.x always; new JAX when the mesh has
    # only DP axes) can gather ZeRO-1 slices with a manual-axis
    # all_gather — no auto axes left for Shardy to un-shard, and half
    # the wire of the zero-pad + psum trick kept for partial-auto.
    manual_all_gather = bool(dp_axes) and \
        compat.full_manual_region(step_manual, mesh)

    # PR 8: the MoE expert-parallel combine wire. Built only when the
    # step can legally run it: MoE model, the profile's EP axes live on
    # this mesh, and every EP axis is manual in the step region (the
    # permute lanes need collective axis names — with no DP axes the
    # step runs under plain jit, so the model keeps the local combine).
    # The exchange codec runs at ratio 2.5 with EF/top-k off: expert
    # outputs are dense payloads, and at 2.5 the sketch capacity covers
    # the block even when every slot is occupied, so recovery — hence
    # the combine itself — is exact (no feedback residue to carry).
    ep_exchange = None
    ep_axes_eff = tuple(ax for ax in prof.ep_axes if ax in mesh.shape)
    if (tc.ep_exchange != "none" and getattr(api.cfg, "moe", None) is not None
            and dp_axes and ep_axes_eff
            and set(ep_axes_eff) <= set(step_manual)):
        ex_cfg = dataclasses.replace(tc.compression, ratio=2.5,
                                     topk_ratio=None, error_feedback=False)
        ep_exchange = agg_lib.make_exchange(
            tc.ep_exchange, ex_cfg, mesh, ep_axes_eff,
            outer_manual=step_manual)

    def make_aggregate(agg):
        def aggregate(grads, residual, pspecs):
            if isinstance(agg, agg_lib.DenseAggregator):
                return coll.dense_all_reduce(grads, dp_axes), residual, None
            res_local = jax.tree.map(
                lambda r: r[0] if r.ndim > 1 else r, residual)
            out, new_state = agg(
                grads, coll.AggregationState(residual=res_local), pspecs)
            new_res = jax.tree.map(
                lambda old, r: r[None] if old.ndim > 1 else old,
                residual, new_state.residual)
            return out, new_res, new_state.telemetry
        return aggregate

    def _dp_rank():
        # Rank-major linearization shared with the collectives layer so
        # ZeRO-1 slice placement matches psum_scatter/all_gather tiling.
        return coll.linear_rank(dp_axes)

    def apply_updates(params, opt, grads, step, pspecs, norm_psum=False):
        lr = opt_lib.lr_schedule(step, ocfg)
        gnorm = opt_lib.global_grad_norm(grads)
        if norm_psum:
            # Gather-skip path: each rank holds a disjoint piece of the
            # aggregated gradient (exact inside its owned coordinates,
            # zero outside), so the global norm is the cross-rank psum
            # of the per-rank squared norms — every coordinate counted
            # exactly once.
            gnorm = jnp.sqrt(jax.lax.psum(gnorm * gnorm, tuple(dp_axes)))
        if ocfg.grad_clip:
            grads = opt_lib.clip_grads(grads, gnorm, ocfg.grad_clip)
        moms = list(opt.keys())

        def leaf_update(path_spec, p, g, *mom_leaves):
            st = {k: v for k, v in zip(moms, mom_leaves)}
            d = (_zero_slice_dim(p.shape, path_spec, dp, False)
                 if (prof.zero1 and dp > 1) else None)
            if d is None:
                new_p, new_st = opt_lib.opt_leaf_update(p, g, st, lr, step, ocfg)
                return new_p, tuple(new_st[k] for k in moms)
            blk = p.shape[d] // dp
            start = _dp_rank() * blk
            p_s = jax.lax.dynamic_slice_in_dim(p, start, blk, axis=d)
            g_s = jax.lax.dynamic_slice_in_dim(g, start, blk, axis=d)
            new_p_s, new_st = opt_lib.opt_leaf_update(p_s, g_s, st, lr, step,
                                                      ocfg)
            # Gather the updated slices. Full-manual regions use the
            # rank-major tiled all_gather (optimal AG ring); partial-auto
            # regions keep the scatter+psum trick instead: Shardy
            # un-shards the auto (TP) axes around a manual-axis
            # all_gather (full-size transient per device) while psum
            # keeps them sharded, at 2x the AG ring's wire. Both add the
            # exact per-rank delta once — bit-identical results.
            delta = (new_p_s - p_s).astype(p.dtype)
            if manual_all_gather:
                new_p = p + jax.lax.all_gather(delta, tuple(dp_axes),
                                               axis=d, tiled=True)
            else:
                full = jnp.zeros(p.shape, p.dtype)
                full = jax.lax.dynamic_update_slice_in_dim(full, delta,
                                                           start, axis=d)
                new_p = p + jax.lax.psum(full, dp_axes)
            return new_p, tuple(new_st[k] for k in moms)

        p_leaves, treedef = jax.tree.flatten(params)
        spec_leaves = treedef.flatten_up_to(pspecs)
        g_leaves = treedef.flatten_up_to(grads)
        mom_leaves = [treedef.flatten_up_to(opt[k]) for k in moms]
        new_p, new_mom = [], [[] for _ in moms]
        for i, (p, s, g) in enumerate(zip(p_leaves, spec_leaves, g_leaves)):
            np_, nst = leaf_update(s, p, g, *[m[i] for m in mom_leaves])
            new_p.append(np_)
            for j in range(len(moms)):
                new_mom[j].append(nst[j])
        params = jax.tree.unflatten(treedef, new_p)
        opt = {k: jax.tree.unflatten(treedef, new_mom[j])
               for j, k in enumerate(moms)}
        return params, opt, gnorm

    def make(state: TrainState):
        specs = state_specs(state, tc, mesh)
        pspecs = specs["pspecs"]

        # ZeRO-1 gather-skip (PR 5): hand the reduce-scatter aggregator
        # the per-leaf slice dims the optimizer will consume. When the
        # stream chunk grid aligns with them, the aggregator feeds each
        # rank's optimizer shard directly and skips the recovered-chunk
        # all_gather; the step then reduces the grad-norm across ranks
        # (the only consumer of off-shard gradient values).
        aggregator_use, norm_psum = aggregator, False
        if (prof.zero1 and tc.rs_gather_skip and dp > 1 and isinstance(
                aggregator, agg_lib.CompressedReduceScatterAggregator)):
            p_leaves, treedef = jax.tree.flatten(state.params)
            spec_leaves = treedef.flatten_up_to(pspecs)
            dims = tuple(_zero_slice_dim(p.shape, s, dp)
                         for p, s in zip(p_leaves, spec_leaves))
            aggregator_use = dataclasses.replace(aggregator,
                                                 zero1_dims=dims)
            norm_psum = aggregator_use.gather_skip_active(state.params,
                                                          pspecs)
        aggregate = make_aggregate(aggregator_use)

        def inner(params, opt, residual, step, batch):
            loss, metrics, grads = local_grads(params, batch, pspecs)
            grads, residual, telemetry = aggregate(grads, residual, pspecs)
            params, opt, gnorm = apply_updates(params, opt, grads, step,
                                               pspecs, norm_psum=norm_psum)
            # cross-worker metric reduction
            loss = jax.lax.psum(loss, dp_axes) / dp if dp_axes else loss
            metrics = {k: (jax.lax.psum(v, dp_axes) / dp if dp_axes else v)
                       for k, v in metrics.items()}
            metrics["grad_norm"] = gnorm
            metrics["loss"] = loss
            if telemetry is not None:
                # Per-bucket occupancy for the `auto` wire-plan
                # controller. Computed from the aggregated stream, so it
                # is already identical on every rank — no reduction.
                metrics["bucket_occupancy"] = telemetry["bucket_occupancy"]
            return params, opt, residual, metrics

        def step_fn(state: TrainState, batch):
            if dp_axes:
                bm, _ = batch_specs(batch, mesh, tc)
                sm = specs["manual"]
                fn = compat.shard_map(
                    inner, mesh=mesh,
                    in_specs=(sm.params, sm.opt, sm.residual, P(), bm),
                    out_specs=(sm.params, sm.opt, sm.residual, P()),
                    axis_names=compat.train_step_manual_axes(mesh, dp_axes),
                    check_vma=False)
            else:
                fn = inner          # no DP axes: pure auto-sharded step
            params, opt, residual, metrics = fn(
                state.params, state.opt, state.residual, state.step, batch)
            return TrainState(params=params, opt=opt, residual=residual,
                              step=state.step + 1), metrics

        return step_fn, specs

    return make
