"""Optimizers in plain JAX (AdamW, SGD-momentum) with dtype-configurable
moments, plus the warmup-cosine schedule.

The update functions are strictly elementwise so they can be applied to
full leaves (replicated optimizer) or to ZeRO-1 shard slices — the caller
decides the granularity.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"           # "adamw" | "momentum"
    lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 200
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    momentum: float = 0.9
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # moments dtype ("bfloat16" for 1T-scale)

    @property
    def _sdt(self):
        return jnp.bfloat16 if self.state_dtype == "bfloat16" else jnp.float32


def lr_schedule(step: jnp.ndarray, cfg: OptimizerConfig) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_opt_state(params: Any, cfg: OptimizerConfig) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, cfg._sdt)
    if cfg.kind == "adamw":
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}
    if cfg.kind == "momentum":
        return {"m": jax.tree.map(zeros, params)}
    raise ValueError(cfg.kind)


def opt_leaf_update(p: jnp.ndarray, g: jnp.ndarray, state: Dict[str, jnp.ndarray],
                    lr: jnp.ndarray, step: jnp.ndarray, cfg: OptimizerConfig
                    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Elementwise update of one leaf (or one ZeRO slice of a leaf)."""
    g = g.astype(jnp.float32)
    pf = p.astype(jnp.float32)
    if cfg.kind == "adamw":
        m = state["m"].astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v = state["v"].astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        t = step.astype(jnp.float32) + 1.0
        mh = m / (1 - cfg.b1 ** t)
        vh = v / (1 - cfg.b2 ** t)
        upd = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf
        new_p = (pf - lr * upd).astype(p.dtype)
        return new_p, {"m": m.astype(state["m"].dtype),
                       "v": v.astype(state["v"].dtype)}
    # momentum
    m = state["m"].astype(jnp.float32) * cfg.momentum + g
    new_p = (pf - lr * m).astype(p.dtype)
    return new_p, {"m": m.astype(state["m"].dtype)}


def global_grad_norm(grads: Any) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    return jnp.sqrt(sq)


def clip_grads(grads: Any, norm: jnp.ndarray, max_norm: float) -> Any:
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads)
