"""Top-level training configuration: aggregation mode (the paper's knob),
parallelism profile, optimizer, memory policy."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.config import CompressionConfig
from repro.parallel.sharding import ShardingProfile
from .optimizer import OptimizerConfig


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    aggregator: str = "compressed"       # "dense" (NCCL-baseline analogue)
                                         # | "compressed" (the paper,
                                         #   bucketed — core/aggregators)
                                         # | "compressed_rs" (peel only
                                         #   this DP-rank's bucket range;
                                         #   pairs with zero1)
                                         # | "compressed_innet" (emulated
                                         #   in-network switch tree —
                                         #   repro.net; wire via
                                         #   compression.wire_dtype)
                                         # | "auto" (PR 6: per-bucket
                                         #   wire plans from the online
                                         #   cost model — core/costmodel;
                                         #   replan cadence via
                                         #   compression.replan_every,
                                         #   plans applied through
                                         #   build_train_step(...,
                                         #   wire_plan=...))
    compression: CompressionConfig = dataclasses.field(
        default_factory=CompressionConfig)
    optimizer: OptimizerConfig = dataclasses.field(
        default_factory=OptimizerConfig)
    sharding: ShardingProfile = dataclasses.field(
        default_factory=ShardingProfile)
    remat: str = "block"                 # "none" | "block" | "dots"
    accum_steps: int = 1                 # microbatch gradient accumulation
    ep_exchange: str = "none"            # PR 8: wire for the MoE expert-
                                         # parallel combine all-to-all.
                                         # "none" keeps the local scatter-
                                         # add combine; "dense" |
                                         # "compressed" route the partial
                                         # expert outputs through
                                         # core/aggregators.make_exchange
                                         # (applied only when the model is
                                         # MoE and the profile's ep_axes
                                         # are manual in the train step)
    rs_gather_skip: bool = True          # with compressed_rs + zero1:
                                         # when the stream chunk grid
                                         # aligns with the ZeRO-1 slices
                                         # (streams.zero1_gather_skip),
                                         # feed per-rank recovered chunks
                                         # straight into the optimizer
                                         # shards and skip the recovered-
                                         # chunk all_gather (the saving
                                         # shows in strategy_wire_bytes);
                                         # False forces the full gather
    seed: int = 0

    def __post_init__(self):
        from repro.core.aggregators import AGGREGATORS, EXCHANGES  # cycle
        if self.aggregator not in AGGREGATORS:
            raise ValueError(
                f"unknown aggregator {self.aggregator!r}; have "
                f"{sorted(AGGREGATORS)}")
        if self.ep_exchange != "none" and self.ep_exchange not in EXCHANGES:
            raise ValueError(
                f"unknown ep_exchange {self.ep_exchange!r}; have "
                f"{['none'] + sorted(EXCHANGES)}")
