"""Top-level training configuration: aggregation mode (the paper's knob),
parallelism profile, optimizer, memory policy."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.config import CompressionConfig
from repro.parallel.sharding import ShardingProfile
from .optimizer import OptimizerConfig


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    aggregator: str = "compressed"       # "dense" (NCCL-baseline analogue)
                                         # | "compressed" (the paper,
                                         #   bucketed — core/aggregators)
                                         # | "compressed_rs" (peel only
                                         #   this DP-rank's bucket range;
                                         #   pairs with zero1)
    compression: CompressionConfig = dataclasses.field(
        default_factory=CompressionConfig)
    optimizer: OptimizerConfig = dataclasses.field(
        default_factory=OptimizerConfig)
    sharding: ShardingProfile = dataclasses.field(
        default_factory=ShardingProfile)
    remat: str = "block"                 # "none" | "block" | "dots"
    accum_steps: int = 1                 # microbatch gradient accumulation
    seed: int = 0

    def __post_init__(self):
        if self.aggregator not in ("dense", "compressed", "compressed_rs"):
            raise ValueError(self.aggregator)
