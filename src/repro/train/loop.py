"""Training loop: step dispatch + checkpointing + failure recovery +
straggler accounting. This is the piece a cluster job actually runs.

Control flow on failure (simulated or real):
  detect -> (optionally shrink world / rebuild mesh) -> restore last
  checkpoint with resharding -> replay the deterministic data stream from
  the restored step -> continue. ``run_training`` survives any number of
  injected failures up to ``RecoveryPolicy.max_restarts``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import batch_fn
from repro.ft.failures import (FailureSimulator, InjectedFailure,
                               RecoveryPolicy, StragglerMonitor)
from repro.models.registry import ModelAPI
from .config import TrainConfig
from .step import (TrainState, init_train_state, build_train_step,
                   batch_specs, state_specs)


@dataclasses.dataclass
class TrainResult:
    losses: List[float]
    metrics: List[Dict[str, float]]
    restarts: int
    straggler_events: List[dict]
    final_step: int
    state: Any


def run_training(api: ModelAPI, tc: TrainConfig, mesh, *,
                 global_batch: int, seq_len: int, steps: int,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 failure_sim: Optional[FailureSimulator] = None,
                 recovery: RecoveryPolicy = RecoveryPolicy(),
                 log_every: int = 10,
                 log_fn: Callable[[str], None] = print) -> TrainResult:
    make_batch = batch_fn(api.cfg, global_batch, seq_len, seed=tc.seed)
    monitor = StragglerMonitor()
    saver = ckpt.AsyncCheckpointer()

    state = init_train_state(api, tc, mesh, jax.random.PRNGKey(tc.seed))
    make = build_train_step(api, tc, mesh)
    step_fn, specs = make(state)
    _, bnamed = batch_specs(make_batch(0), mesh, tc)
    jitted = jax.jit(step_fn, in_shardings=(specs["named"], bnamed),
                     out_shardings=(specs["named"], None),
                     donate_argnums=(0,))
    state = jax.device_put(state, specs["named"])

    # resume if a checkpoint exists
    start = 0
    if ckpt_dir and (last := ckpt.latest_step(ckpt_dir)) is not None:
        state = ckpt.restore(ckpt_dir, last, template=state,
                             shardings=specs["named"])
        start = last
        log_fn(f"[loop] resumed from checkpoint step {start}")

    losses: List[float] = []
    all_metrics: List[Dict[str, float]] = []
    restarts = 0
    step = start
    while step < steps:
        try:
            t0 = time.perf_counter()
            if failure_sim is not None:
                failure_sim.check(step)
            batch = jax.tree.map(lambda a, s: jax.device_put(a, s),
                                 make_batch(step), bnamed)
            state, metrics = jitted(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            monitor.observe(step, dt)
            losses.append(loss)
            # vector metrics (e.g. the `auto` strategy's per-bucket
            # occupancy telemetry) are kept as lists, scalars as floats
            all_metrics.append({
                k: float(v) if np.ndim(v) == 0
                else np.asarray(v).tolist()
                for k, v in metrics.items()})
            if log_every and step % log_every == 0:
                log_fn(f"[loop] step {step} loss {loss:.4f} "
                       f"({dt*1e3:.0f} ms)")
            step += 1
            if ckpt_dir and step % ckpt_every == 0:
                saver.save(ckpt_dir, step, state,
                           metadata={"loss": loss})
        except InjectedFailure as e:
            restarts += 1
            log_fn(f"[loop] FAILURE detected: {e}; restart {restarts}")
            if restarts > recovery.max_restarts:
                raise
            if ckpt_dir is None:
                raise
            saver.wait()
            last = ckpt.latest_step(ckpt_dir)
            if last is None:
                # no checkpoint yet: restart from scratch
                state = jax.device_put(
                    init_train_state(api, tc, mesh,
                                     jax.random.PRNGKey(tc.seed)),
                    specs["named"])
                step = 0
            else:
                state = ckpt.restore(ckpt_dir, last, template=state,
                                     shardings=specs["named"])
                step = last
            log_fn(f"[loop] recovered at step {step}")

    saver.wait()
    return TrainResult(losses=losses, metrics=all_metrics, restarts=restarts,
                       straggler_events=monitor.events, final_step=step,
                       state=state)
